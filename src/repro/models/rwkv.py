"""RWKV-6 (Finch): token-shift time-mix with data-dependent decay + channel-mix.

WKV recurrence per head (state S: (dk, dv)):
    o_t = r_t @ (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
with per-token per-channel decay w_t in (0,1) produced by a LoRA on the
shifted input (the paper's data-dependent decay).

Paths:
  * ``wkv_ref``      — lax.scan oracle (+ decode single step),
  * ``wkv_chunked``  — chunk-sequential, intra-chunk parallel (the form the
                        Pallas kernel implements; pure-jnp here),
  * Pallas kernel    — repro.kernels.rwkv_scan (selected via kernel_mode).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain
from repro.models.layers import Params, dense_init

LORA_DIM_DECAY = 64
LORA_DIM_MIX = 32
N_MIX = 5  # r, k, v, w, g


def rwkv_dims(cfg: ArchConfig) -> Tuple[int, int]:
    hd = cfg.rwkv_head_dim
    return cfg.d_model // hd, hd  # (heads, head_dim)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def tmix_init(rng, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    h, hd = rwkv_dims(cfg)
    keys = jax.random.split(rng, 10)
    return {
        "mu_x": jnp.full((d,), 0.5, jnp.float32),
        "mu": jnp.full((N_MIX, d), 0.5, jnp.float32),  # r,k,v,w,g bases
        "mix_w1": dense_init(keys[0], d, N_MIX * LORA_DIM_MIX, jnp.float32),
        "mix_w2": (
            jax.random.normal(keys[1], (N_MIX, LORA_DIM_MIX, d), jnp.float32) * 0.02
        ),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),
        "decay_w1": dense_init(keys[2], d, LORA_DIM_DECAY, jnp.float32),
        "decay_w2": dense_init(keys[3], LORA_DIM_DECAY, d, jnp.float32),
        "bonus": (jax.random.normal(keys[4], (h, hd), jnp.float32) * 0.02),
        "wr": dense_init(keys[5], d, d, dtype),
        "wk": dense_init(keys[6], d, d, dtype),
        "wv": dense_init(keys[7], d, d, dtype),
        "wg": dense_init(keys[8], d, d, dtype),
        "wo": dense_init(keys[9], d, d, dtype),
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def cmix_init(rng, cfg: ArchConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(rng, 3)
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": jnp.full((d,), 0.5, jnp.float32),
        "mu_r": jnp.full((d,), 0.5, jnp.float32),
        "wk": dense_init(k1, d, f, dtype),
        "wv": dense_init(k2, f, d, dtype),
        "wr": dense_init(k3, d, d, dtype),
    }


# ---------------------------------------------------------------------------
# WKV core
# ---------------------------------------------------------------------------


def wkv_ref(
    r: jnp.ndarray,  # (b, s, h, dk) fp32
    k: jnp.ndarray,  # (b, s, h, dk)
    v: jnp.ndarray,  # (b, s, h, dv)
    w: jnp.ndarray,  # (b, s, h, dk) decay in (0,1), fp32
    u: jnp.ndarray,  # (h, dk) bonus
    s0: jnp.ndarray | None = None,  # (b, h, dk, dv)
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp  # (b, h, d*)
        kv = k_t[..., :, None] * v_t[..., None, :]  # (b, h, dk, dv)
        o = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., :, None] * kv)
        state = w_t[..., :, None] * state + kv
        return state, o

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    s_final, os = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(os, 0, 1), s_final  # (b, s, h, dv), (b, h, dk, dv)


def wkv_chunked(
    r: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    w: jnp.ndarray,
    u: jnp.ndarray,
    *,
    chunk: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-sequential WKV. Within each chunk of length L:
        o_t = (r_t * prod_{s<=t-1} w) @ S_0
            + sum_{s<t} [sum_c r_t[c] k_s[c] e^{cum[t-1,c]-cum[s,c]}] v_s
            + (r_t . (u*k_t)) v_t
    computed with an explicit (L, L, dk) decay tensor per (b, h) — the exact
    math the Pallas kernel tiles in VMEM.
    """
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if s % chunk != 0:
        return wkv_ref(r, k, v, w, u)
    n_chunks = s // chunk
    L = chunk

    def rearr(t):
        return jnp.moveaxis(t.reshape(b, n_chunks, L, h, t.shape[-1]), 1, 0)

    r_c, k_c, v_c, w_c = rearr(r), rearr(k), rearr(v), rearr(w)

    def chunk_step(state, inp):  # state: (b, h, dk, dv)
        r_t, k_t, v_t, w_t = inp  # (b, L, h, d)
        logw = jnp.log(w_t)  # negative
        cum = jnp.cumsum(logw, axis=1)  # (b, L, h, dk): cum[t] = sum_{s<=t} log w_s
        cum_prev = cum - logw  # cum[t-1] with cum[-1] = 0
        # inter-chunk: r decayed to chunk start
        r_dec = r_t * jnp.exp(cum_prev)
        o_inter = jnp.einsum("blhk,bhkv->blhv", r_dec, state)
        # intra-chunk: pairwise scores with per-channel decay
        decay_ts = jnp.exp(
            cum_prev[:, :, None] - cum[:, None, :]
        )  # (b, t, s, h, dk) = e^{cum[t-1]-cum[s]}
        mask = (jnp.arange(L)[:, None] > jnp.arange(L)[None, :])[None, :, :, None]
        scores = jnp.einsum(
            "blhk,bmhk,blmhk->blmh",
            r_t,
            k_t,
            jnp.where(mask[..., None], decay_ts, 0.0),
        )
        o_intra = jnp.einsum("blmh,bmhv->blhv", scores, v_t)
        # diagonal bonus term
        diag = jnp.einsum("blhk,hk,blhk->blh", r_t, u, k_t)
        o_diag = diag[..., None] * v_t
        o = o_inter + o_intra + o_diag
        # state update to end of chunk
        decay_to_end = jnp.exp(cum[:, -1:, :, :] - cum)  # (b, L, h, dk)
        k_dec = k_t * decay_to_end
        state = jnp.exp(cum[:, -1])[..., :, None] * state + jnp.einsum(
            "blhk,blhv->bhkv", k_dec, v_t
        )
        return state, o

    s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    s_final, os = jax.lax.scan(chunk_step, s0, (r_c, k_c, v_c, w_c))
    o = jnp.moveaxis(os, 0, 1).reshape(b, s, h, dv)
    return o, s_final


# ---------------------------------------------------------------------------
# Time-mix / channel-mix blocks
# ---------------------------------------------------------------------------


def _token_shift(x: jnp.ndarray, prev: jnp.ndarray | None = None) -> jnp.ndarray:
    """x_{t-1}; first position uses `prev` (decode carry) or zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """Data-dependent token-shift interpolation producing the 5 mixed streams."""
    xx = (x_prev - x).astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    base = x32 + xx * p["mu_x"]
    lora = jnp.tanh(jnp.einsum("bsd,dm->bsm", base, p["mix_w1"]))
    lora = lora.reshape(*lora.shape[:-1], N_MIX, LORA_DIM_MIX)
    delta = jnp.einsum("bsnm,nmd->bsnd", lora, p["mix_w2"])  # (b,s,5,d)
    mixed = x32[:, :, None] + xx[:, :, None] * (p["mu"] + delta)
    return tuple(mixed[:, :, i] for i in range(N_MIX))  # r,k,v,w,g streams


def _group_norm(x: jnp.ndarray, scale: jnp.ndarray, h: int, eps: float = 64e-5) -> jnp.ndarray:
    """Per-head layer norm of the wkv output (rwkv's ln_x)."""
    b, s, d = x.shape
    xh = x.reshape(b, s, h, d // h).astype(jnp.float32)
    mean = jnp.mean(xh, axis=-1, keepdims=True)
    var = jnp.var(xh, axis=-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    return (xh.reshape(b, s, d) * scale).astype(x.dtype)


def tmix_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,
    *,
    kernel_mode: str = "reference",
    chunk: int = 64,
    shift_prev: jnp.ndarray | None = None,
    s0: jnp.ndarray | None = None,
    return_state: bool = False,
):
    h, hd = rwkv_dims(cfg)
    b, s, d = x.shape
    x_prev = _token_shift(x, shift_prev)
    xr, xk, xv, xw, xg = _ddlerp(p, x, x_prev)
    dt = x.dtype
    r = jnp.einsum("bsd,de->bse", xr.astype(dt), p["wr"])
    k = jnp.einsum("bsd,de->bse", xk.astype(dt), p["wk"])
    v = jnp.einsum("bsd,de->bse", xv.astype(dt), p["wv"])
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg.astype(dt), p["wg"]))
    # data-dependent decay (fp32)
    decay_lora = jnp.einsum(
        "bsd,de->bse", jnp.tanh(jnp.einsum("bsd,dm->bsm", xw, p["decay_w1"])), p["decay_w2"]
    )
    w = jnp.exp(-jnp.exp(p["decay_base"] + decay_lora))  # (b, s, d) in (0,1)

    def heads(t):
        return t.reshape(b, s, h, hd)

    r4, k4, v4, w4 = (
        heads(r).astype(jnp.float32),
        heads(k).astype(jnp.float32),
        heads(v).astype(jnp.float32),
        heads(w.astype(jnp.float32)),
    )
    r4 = constrain(r4, ("data", None, "model", None))
    if s == 1:
        o, s_final = wkv_ref(r4, k4, v4, w4, p["bonus"], s0)
    elif kernel_mode == "pallas":
        from repro.kernels.rwkv_scan import ops as wkv_ops

        o, s_final = wkv_ops.wkv6(r4, k4, v4, w4, p["bonus"], chunk=chunk)
    elif kernel_mode == "chunked":
        o, s_final = wkv_chunked(r4, k4, v4, w4, p["bonus"], chunk=chunk)
    else:
        o, s_final = wkv_ref(r4, k4, v4, w4, p["bonus"], s0)
    o = o.reshape(b, s, d).astype(x.dtype)
    o = _group_norm(o, p["ln_x"], h)
    out = jnp.einsum("bsd,de->bse", o * g, p["wo"])
    if return_state:
        return out, (x[:, -1:], s_final)
    return out


def cmix_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,
    *,
    shift_prev: jnp.ndarray | None = None,
    return_state: bool = False,
):
    x_prev = _token_shift(x, shift_prev)
    xx = (x_prev - x).astype(jnp.float32)
    x32 = x.astype(jnp.float32)
    xk = (x32 + xx * p["mu_k"]).astype(x.dtype)
    xr = (x32 + xx * p["mu_r"]).astype(x.dtype)
    k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, p["wk"])))
    kv = jnp.einsum("bsf,fd->bsd", k, p["wv"])
    out = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"])) * kv
    if return_state:
        return out, x[:, -1:]
    return out


def rwkv_init_state(cfg: ArchConfig, batch: int, dtype) -> Dict:
    h, hd = rwkv_dims(cfg)
    return {
        "tmix_shift": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
        "cmix_shift": jnp.zeros((cfg.n_layers, batch, 1, cfg.d_model), dtype),
        "wkv": jnp.zeros((cfg.n_layers, batch, h, hd, hd), jnp.float32),
    }
