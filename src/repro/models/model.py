"""Model zoo: ``build_model(cfg)`` -> a :class:`Model` with init/apply/loss/
prefill/decode. Handles the modality frontends (audio frames, vision
patches + M-RoPE) and the vocab head with seq-chunked cross-entropy so the
full (seq, vocab) logit tensor is never materialized.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain
from repro.models import attention, moe, rwkv, ssm, transformer
from repro.models.layers import (
    mlp_apply,
    Params,
    embed_init,
    positions_from_tokens,
    rmsnorm,
    rmsnorm_init,
    softmax_cross_entropy,
)


@dataclass
class ModelOptions:
    kernel_mode: str = "reference"  # reference | chunked | pallas
    remat: bool = True
    scan_layers: bool = True
    ssm_chunk: int = 128
    wkv_chunk: int = 64
    moe_group: int = 4096
    attn_q_chunk: int = 4096
    loss_chunk: int = 512
    decode_cache_mode: str = "carry"  # carry | stream (see transformer.stack_decode)
    kv_quantized: bool = False  # int8 KV cache (decode serving)
    aux_coeff: float = 0.01
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"


class Model:
    def __init__(self, cfg: ArchConfig, opts: Optional[ModelOptions] = None):
        self.cfg = cfg
        self.opts = opts or ModelOptions()

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------

    def init(self, rng) -> Params:
        cfg = self.cfg
        dtype = jnp.dtype(self.opts.param_dtype)
        k_embed, k_layers, k_head = jax.random.split(rng, 3)
        params: Params = {}
        if cfg.frontend != "audio_frames":
            params["embed"] = {"table": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)}
        layer_rngs = jax.random.split(k_layers, cfg.n_layers)
        params["layers"] = jax.vmap(
            lambda r: transformer.layer_init(r, cfg, dtype)
        )(layer_rngs)
        params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            params["lm_head"] = {"table": embed_init(k_head, cfg.vocab_size, cfg.d_model, dtype)}
        return params

    def abstract_params(self, rng=None) -> Params:
        rng = rng if rng is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, rng)

    # ------------------------------------------------------------------
    # Embedding / head
    # ------------------------------------------------------------------

    def _compute_dtype(self):
        return jnp.dtype(self.opts.compute_dtype)

    def _embed(self, params: Params, batch: Dict[str, jnp.ndarray]) -> jnp.ndarray:
        cfg = self.cfg
        cdt = self._compute_dtype()
        if cfg.frontend == "audio_frames":
            x = batch["frame_embeds"].astype(cdt)
        else:
            table = params["embed"]["table"]
            x = jnp.take(table, batch["tokens"], axis=0).astype(cdt)
            if cfg.scale_embeddings:
                x = x * jnp.asarray(cfg.d_model ** 0.5, cdt)
        if cfg.frontend == "vision_patches" and "patch_embeds" in batch:
            n = batch["patch_embeds"].shape[1]
            if x.shape[1] >= n:  # splice patch embeddings over the first n slots
                x = jax.lax.dynamic_update_slice(
                    x, batch["patch_embeds"].astype(cdt), (0, 0, 0)
                )
        return x

    def _head_table(self, params: Params) -> jnp.ndarray:
        if self.cfg.tie_embeddings:
            return params["embed"]["table"]
        return params["lm_head"]["table"]

    def _positions(self, batch: Dict, b: int, s: int, offset=0) -> jnp.ndarray:
        if self.cfg.rope_variant == "mrope":
            return batch["positions"]
        return positions_from_tokens(b, s, offset)

    # ------------------------------------------------------------------
    # Forward (train) + loss
    # ------------------------------------------------------------------

    def _trunk(self, params: Params, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
        cfg, o = self.cfg, self.opts
        cdt = self._compute_dtype()
        cast = lambda t: t.astype(cdt) if t.dtype in (jnp.float32, jnp.bfloat16) else t
        layers = jax.tree_util.tree_map(cast, params["layers"])
        x = self._embed(params, batch)
        x = constrain(x, ("data", None, None))
        b, s = x.shape[0], x.shape[1]
        positions = self._positions(batch, b, s)
        x, aux = transformer.stack_apply(
            layers, cfg, x, positions,
            kernel_mode=o.kernel_mode, remat=o.remat, scan_layers=o.scan_layers,
            ssm_chunk=o.ssm_chunk, wkv_chunk=o.wkv_chunk, moe_group=o.moe_group,
            attn_q_chunk=o.attn_q_chunk,
        )
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x, aux

    def apply(self, params: Params, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Full logits (small models / tests only)."""
        x, aux = self._trunk(params, batch)
        table = self._head_table(params).astype(self._compute_dtype())
        logits = jnp.einsum("bsd,vd->bsv", x, table)
        return logits, aux

    def loss(self, params: Params, batch: Dict) -> jnp.ndarray:
        """Causal LM loss with seq-chunked head (never materializes the full
        fp32 logit tensor)."""
        x, aux = self._trunk(params, batch)
        labels = batch["labels"]
        table = self._head_table(params).astype(self._compute_dtype())
        b, s, d = x.shape
        chunk = min(self.opts.loss_chunk, s)
        if s % chunk != 0:
            chunk = s
        n_chunks = s // chunk
        xc = jnp.moveaxis(x.reshape(b, n_chunks, chunk, d), 1, 0)
        lc = jnp.moveaxis(labels.reshape(b, n_chunks, chunk), 1, 0)

        @functools.partial(jax.checkpoint, prevent_cse=False)
        def chunk_nll(carry, inp):
            xc_i, lc_i = inp
            logits = jnp.einsum("bsd,vd->bsv", xc_i, table).astype(jnp.float32)
            logz = jax.scipy.special.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc_i[..., None], axis=-1)[..., 0]
            return carry + jnp.sum(logz - gold), None

        total, _ = jax.lax.scan(chunk_nll, jnp.zeros((), jnp.float32), (xc, lc))
        nll = total / (b * s)
        return nll + self.opts.aux_coeff * aux

    # ------------------------------------------------------------------
    # Serving: prefill + decode
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int, stacked: bool = True):
        """Decode state. ``stacked`` -> leaves carry a leading n_layers axis
        (scan decode); otherwise a tuple of per-layer dicts (unrolled decode
        — each layer's buffer donates/aliases independently)."""
        cfg = self.cfg
        cdt = self._compute_dtype()
        cache: Dict[str, Any] = {}
        if cfg.family == "ssm":
            cache.update(rwkv.rwkv_init_state(cfg, batch, cdt))
        else:
            cap = attention.cache_capacity(cfg, max_len)
            cache.update(
                attention.init_kv_cache(
                    cfg, batch, cap, cdt, quantized=self.opts.kv_quantized
                )
            )
            if cfg.family == "hybrid":
                cache.update(ssm.ssm_init_state(cfg, batch, cdt))
        if stacked:
            return cache
        return unstack_cache(cache, cfg.n_layers)

    def prefill(
        self, params: Params, batch: Dict, max_len: Optional[int] = None
    ) -> Tuple[jnp.ndarray, Dict]:
        """Run the full prompt once; return (last-token logits, filled cache).

        One scan produces both the trunk output and the per-layer K/V /
        recurrent states (the cache leaves come out of the scan's ys with a
        leading n_layers axis, matching ``init_cache`` layout).

        ``max_len`` sizes the emitted KV cache (room for decode steps);
        defaults to the prompt length (no extra slots).
        """
        x, cache = self._prefill_trunk(params, batch, max_len=max_len)
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        table = self._head_table(params).astype(self._compute_dtype())
        logits = jnp.einsum("bd,vd->bv", x[:, -1], table)
        return logits, cache

    def _prefill_trunk(self, params: Params, batch: Dict, max_len: Optional[int] = None):
        """Trunk pass that also captures per-layer K/V (and recurrent states)."""
        cfg, o = self.cfg, self.opts
        cdt = self._compute_dtype()
        cast = lambda t: t.astype(cdt) if t.dtype in (jnp.float32, jnp.bfloat16) else t
        layers = jax.tree_util.tree_map(cast, params["layers"])
        x = self._embed(params, batch)
        b, s = x.shape[0], x.shape[1]
        positions = self._positions(batch, b, s)
        cfg_cap = (
            attention.cache_capacity(cfg, max_len if max_len is not None else s)
            if cfg.family != "ssm"
            else 0
        )

        def body(carry, layer_p):
            xx = carry
            caches = {}
            if cfg.family == "ssm":
                h = rmsnorm(layer_p["norm1"], xx, cfg.norm_eps)
                out, (shift, s_final) = rwkv.tmix_apply(
                    layer_p["tmix"], cfg, h,
                    kernel_mode="chunked", chunk=o.wkv_chunk, return_state=True,
                )
                xx = xx + out
                h = rmsnorm(layer_p["norm2"], xx, cfg.norm_eps)
                out, cshift = rwkv.cmix_apply(
                    layer_p["cmix"], cfg, h, return_state=True
                )
                xx = xx + out
                caches = {"tmix_shift": shift, "cmix_shift": cshift, "wkv": s_final}
                return xx, caches
            h = rmsnorm(layer_p["attn_norm"], xx, cfg.norm_eps)
            pa = layer_p["attn"]
            q, k, v = attention._project_qkv(pa, cfg, h)
            q, k = attention._apply_positions(cfg, q, k, positions)
            # Capture the last `cap` tokens' K/V. Ring caches (SWA) align to
            # ring order: slot of token p is p % cap (identity when
            # s % cap == 0). Short prompts / linear caches pad at the end so
            # decode steps have room.
            if s >= cfg_cap:
                k_cache, v_cache = k[:, -cfg_cap:], v[:, -cfg_cap:]
                if cfg.sliding_window > 0 and s % cfg_cap != 0:
                    k_cache = jnp.roll(k_cache, s % cfg_cap, axis=1)
                    v_cache = jnp.roll(v_cache, s % cfg_cap, axis=1)
            else:
                pad = ((0, 0), (0, cfg_cap - s), (0, 0), (0, 0))
                k_cache, v_cache = jnp.pad(k, pad), jnp.pad(v, pad)
            if o.kv_quantized:  # serve pipeline stores int8 KV end-to-end
                caches["k"], caches["k_scale"] = attention.quantize_kv(k_cache)
                caches["v"], caches["v_scale"] = attention.quantize_kv(v_cache)
            else:
                caches["k"], caches["v"] = k_cache, v_cache
            if cfg.sliding_window > 0:
                attn_out = attention.sliding_window_attention(q, k, v, cfg.sliding_window)
            elif s > o.attn_q_chunk:
                attn_out = attention.causal_chunked_attention(q, k, v, o.attn_q_chunk)
            else:
                attn_out = attention.full_attention(q, k, v, causal=True)
            attn_out = jnp.einsum(
                "...e,ed->...d", attn_out.reshape(b, s, cfg.q_dim), pa["wo"]
            )
            if cfg.family == "hybrid":
                ssm_out, (h_final, conv_state) = ssm.ssm_apply(
                    layer_p["ssm"], cfg, h, chunk=o.ssm_chunk, return_state=True
                )
                caches["h"] = h_final
                caches["conv"] = conv_state
                attn_out = 0.5 * (attn_out + ssm_out)
            xx = xx + attn_out
            h = rmsnorm(layer_p["mlp_norm"], xx, cfg.norm_eps)
            if cfg.is_moe:
                mlp_out, _ = moe.moe_apply(
                    layer_p["moe"], cfg, h, group_size=o.moe_group
                )
            else:
                mlp_out = mlp_apply(layer_p["mlp"], h, cfg.gated_act)
            return xx + mlp_out, caches

        x, cache = jax.lax.scan(body, x, layers)
        return x, cache

    def decode(
        self, params: Params, batch: Dict, cache: Dict, pos: jnp.ndarray
    ) -> Tuple[jnp.ndarray, Dict]:
        """One token for every sequence in the batch. pos: scalar count of
        tokens already in the cache."""
        cfg, o = self.cfg, self.opts
        cdt = self._compute_dtype()
        cast = lambda t: t.astype(cdt) if t.dtype in (jnp.float32, jnp.bfloat16) else t
        layers = jax.tree_util.tree_map(cast, params["layers"])
        x = self._embed(params, batch)
        b = x.shape[0]
        if cfg.rope_variant == "mrope":
            positions = batch["positions"]
        else:
            positions = jnp.broadcast_to(pos.astype(jnp.int32), (b, 1))
        # per-layer (tuple) caches imply the unrolled path; stacked -> scan
        scan_layers = not isinstance(cache, (list, tuple))
        x, new_cache = transformer.stack_decode(
            layers, cfg, x, positions, cache, pos, scan_layers=scan_layers,
            cache_mode=o.decode_cache_mode,
        )
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        table = self._head_table(params).astype(cdt)
        logits = jnp.einsum("bsd,vd->bsv", x, table)
        return logits, new_cache


def unstack_cache(cache: Dict, n_layers: int) -> Tuple:
    """(L, ...)-stacked cache -> tuple of per-layer dicts (views)."""
    return tuple(
        jax.tree_util.tree_map(lambda t, i=i: t[i], cache) for i in range(n_layers)
    )


def build_model(cfg: ArchConfig, opts: Optional[ModelOptions] = None) -> Model:
    return Model(cfg, opts)
