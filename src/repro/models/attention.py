"""Attention: GQA/MQA, sliding windows, qk-norm, KV caches (linear + ring).

Three execution paths share one interface:
  * reference jnp attention (always available; the numerical oracle),
  * chunked banded attention for sliding windows (exact, sub-quadratic),
  * the Pallas flash-attention kernel (``repro.kernels.flash_attention``),
    selected via ``kernel_mode='pallas'`` on TPU targets.

Shapes follow (batch, seq, heads, head_dim) throughout.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain, constrain_weight
from repro.models.layers import (
    Params,
    apply_mrope,
    apply_rope,
    dense_init,
    rmsnorm_head,
)

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def attention_init(rng, cfg: ArchConfig, dtype) -> Params:
    kq, kk, kv, ko = jax.random.split(rng, 4)
    p: Params = {
        "wq": dense_init(kq, cfg.d_model, cfg.q_dim, dtype),
        "wk": dense_init(kk, cfg.d_model, cfg.kv_dim, dtype),
        "wv": dense_init(kv, cfg.d_model, cfg.kv_dim, dtype),
        "wo": dense_init(ko, cfg.q_dim, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


# ---------------------------------------------------------------------------
# Core softmax attention (reference path)
# ---------------------------------------------------------------------------


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d
    )


def full_attention(
    q: jnp.ndarray,  # (b, sq, hq, d)
    k: jnp.ndarray,  # (b, sk, hkv, d)
    v: jnp.ndarray,  # (b, sk, hkv, d)
    *,
    causal: bool = True,
    q_offset: int | jnp.ndarray = 0,
    kv_mask: Optional[jnp.ndarray] = None,  # (b, sk) valid-key mask
) -> jnp.ndarray:
    """Exact softmax attention with grouped KV heads. fp32 softmax."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    scale = d ** -0.5
    qg = q.reshape(b, sq, hkv, n_rep, d)
    logits = jnp.einsum(
        "bqhrd,bkhd->bhrqk", qg, k, preferred_element_type=jnp.float32
    ) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(k.shape[1])[None, :]
        mask = qpos >= kpos  # (sq, sk)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    if kv_mask is not None:
        logits = jnp.where(kv_mask[:, None, None, None, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, hq, d)


def causal_chunked_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    q_chunk: int,
) -> jnp.ndarray:
    """Exact causal attention, one query chunk per lax.scan step against the
    full (masked) key set. The scan bounds live fp32 score memory to one
    (q_chunk x seq) slab — an unrolled per-chunk loop leaves every chunk's
    buffers schedulable-concurrently and blows the memory budget at 32k.
    Cost: the masked rectangle doubles the ideal triangle FLOPs; the
    Pallas flash-attention kernel (kernel_mode='pallas') removes both the
    memory AND the waste on real TPUs; useful_flops_ratio reports it."""
    b, s, hq, d = q.shape
    if s <= q_chunk or s % q_chunk != 0:
        return full_attention(q, k, v, causal=True)
    n_chunks = s // q_chunk
    qc = jnp.moveaxis(q.reshape(b, n_chunks, q_chunk, hq, d), 1, 0)

    def body(_, inp):
        q_i, idx = inp
        o = full_attention(q_i, k, v, causal=True, q_offset=idx * q_chunk)
        return None, o

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(n_chunks)))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, d)


def sliding_window_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    window: int,
) -> jnp.ndarray:
    """Exact causal sliding-window attention, computed band-block-wise
    (scan over query chunks) so the live score tensor is
    O(window * 2window) rather than O(seq^2).

    Each query chunk of length W attends to its own chunk and the previous
    chunk, with the (causal AND within-window) mask applied. Numerics match
    full attention + window mask exactly.
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    if s <= window or s % window != 0:
        # fall back to masked full attention for short/ragged sequences
        return _windowed_full(q, k, v, window)
    w = window
    n_chunks = s // w
    n_rep = hq // hkv
    qc = jnp.moveaxis(q.reshape(b, n_chunks, w, hq, d), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, w, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, w, hkv, d), 1, 0)
    # previous chunk for keys/values (zeros before the first chunk)
    k_prev = jnp.concatenate([jnp.zeros_like(kc[:1]), kc[:-1]], axis=0)
    v_prev = jnp.concatenate([jnp.zeros_like(vc[:1]), vc[:-1]], axis=0)

    qpos = jnp.arange(w)[:, None] + w  # position within the 2w key window
    kpos = jnp.arange(2 * w)[None, :]
    band = (qpos >= kpos) & (kpos > qpos - w)  # causal AND within window
    first_ok = jnp.arange(2 * w)[None, :] >= w

    def chunk_attn(carry, inp):
        idx = carry
        q_i, k_i, v_i, kp, vp = inp  # (b, w, h, d)
        k2 = jnp.concatenate([kp, k_i], axis=1)  # (b, 2w, hkv, d)
        v2 = jnp.concatenate([vp, v_i], axis=1)
        qg = q_i.reshape(b, w, hkv, n_rep, d)
        logits = jnp.einsum(
            "bqhrd,bkhd->bhrqk", qg, k2, preferred_element_type=jnp.float32
        ) * (d ** -0.5)
        valid = jnp.where(idx == 0, band & first_ok, band)
        logits = jnp.where(valid[None, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        o = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(v2.dtype), v2)
        return idx + 1, o.reshape(b, w, hq, d)

    _, outs = jax.lax.scan(chunk_attn, jnp.zeros((), jnp.int32), (qc, kc, vc, k_prev, v_prev))
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, hq, d)


def _windowed_full(q, k, v, window):
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    n_rep = hq // hkv
    qg = q.reshape(b, s, hkv, n_rep, d)
    logits = jnp.einsum(
        "bqhrd,bkhd->bhrqk", qg, k, preferred_element_type=jnp.float32
    ) * (d ** -0.5)
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = (qpos >= kpos) & (kpos > qpos - window)
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhrqk,bkhd->bqhrd", probs.astype(v.dtype), v)
    return out.reshape(b, s, hq, d)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + cache handling)
# ---------------------------------------------------------------------------


def _project_qkv(p: Params, cfg: ArchConfig, x: jnp.ndarray):
    wq = constrain_weight(p["wq"], (None, "model"))
    wk = constrain_weight(p["wk"], (None, "model"))
    wv = constrain_weight(p["wv"], (None, "model"))
    q = jnp.einsum("...d,de->...e", x, wq)
    k = jnp.einsum("...d,de->...e", x, wk)
    v = jnp.einsum("...d,de->...e", x, wv)
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    b, s = x.shape[0], x.shape[1]
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rmsnorm_head(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm_head(p["k_norm"], k, cfg.norm_eps)
    return q, k, v


def _apply_positions(cfg: ArchConfig, q, k, positions):
    if cfg.rope_variant == "none":
        return q, k
    if cfg.rope_variant == "mrope":
        q = apply_mrope(q, positions, cfg.rope_theta)
        k = apply_mrope(k, positions, cfg.rope_theta)
        return q, k
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k


def attention_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (b, s, d_model)
    positions: jnp.ndarray,  # rope: (b, s); mrope: (b, 3, s)
    *,
    kernel_mode: str = "reference",
    q_chunk: int = 4096,
) -> jnp.ndarray:
    """Training / prefill path over the full sequence (causal)."""
    q, k, v = _project_qkv(p, cfg, x)
    q, k = _apply_positions(cfg, q, k, positions)
    q = constrain(q, ("data", None, "model", None))
    k = constrain(k, ("data", None, None, None))
    v = constrain(v, ("data", None, None, None))
    s = x.shape[1]
    if kernel_mode == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops

        out = fa_ops.flash_attention(
            q, k, v, causal=True, window=cfg.sliding_window or None
        )
    elif cfg.sliding_window > 0:
        out = sliding_window_attention(q, k, v, cfg.sliding_window)
    elif s > q_chunk:
        out = causal_chunked_attention(q, k, v, q_chunk)
    else:
        out = full_attention(q, k, v, causal=True)
    b = x.shape[0]
    wo = constrain_weight(p["wo"], ("model", None))
    return jnp.einsum("...e,ed->...d", out.reshape(b, s, cfg.q_dim), wo)


# ---------------------------------------------------------------------------
# KV caches
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: ArchConfig, batch: int, capacity: int, dtype, quantized: bool = False
) -> Dict:
    """Per-layer stacked cache. For sliding-window archs the capacity should
    be the window size (ring buffer); otherwise the max context length.

    ``quantized``: int8 values + one fp16 scale per (token, head) — halves
    (vs bf16) the dominant HBM consumer of long-context decode. The MHA
    archs (kv=40 at 32k x 128) do not fit 16 GB/chip any other way."""
    shape = (cfg.n_layers, batch, capacity, cfg.n_kv_heads, cfg.head_dim)
    if not quantized:
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    sshape = shape[:-1]
    return {
        "k": jnp.zeros(shape, jnp.int8),
        "v": jnp.zeros(shape, jnp.int8),
        "k_scale": jnp.zeros(sshape, jnp.float16),
        "v_scale": jnp.zeros(sshape, jnp.float16),
    }


def quantize_kv(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(..., head_dim) -> int8 values + fp16 per-vector scale."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale.astype(jnp.float16)


def dequantize_kv(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)[..., None]).astype(dtype)


def cache_capacity(cfg: ArchConfig, seq_len: int) -> int:
    if cfg.sliding_window > 0:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def decode_attention_chunked(
    q: jnp.ndarray,  # (b, 1, hq, d)
    k: jnp.ndarray,  # (b, cap, hkv, d) -- bf16 or int8
    v: jnp.ndarray,  # (b, cap, hkv, d)
    kv_mask: jnp.ndarray,  # (b, cap)
    chunk: int = 2048,
    scales=None,  # (k_scale, v_scale): (b, cap, hkv) fp16 when int8 cache
    out_dtype=None,
) -> jnp.ndarray:
    """Flash-decoding: online-softmax scan over KV-cache chunks, so the
    fp32 working set is one (b, chunk) slab instead of the whole cache —
    the same reason the kernel exists on GPUs, re-expressed as a lax.scan
    for the XLA scheduler. int8 caches dequantize per chunk."""
    b, cap, hkv, d = k.shape
    hq = q.shape[2]
    n_rep = hq // hkv
    out_dtype = out_dtype or (v.dtype if scales is None else jnp.bfloat16)
    if cap % chunk != 0:
        if scales is not None:
            k = dequantize_kv(k, scales[0], out_dtype)
            v = dequantize_kv(v, scales[1], out_dtype)
        return full_attention(q, k, v, causal=False, kv_mask=kv_mask)
    n_chunks = cap // chunk
    scale = d ** -0.5
    qg = q.reshape(b, 1, hkv, n_rep, d)
    kc = jnp.moveaxis(k.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, n_chunks, chunk, hkv, d), 1, 0)
    mc = jnp.moveaxis(kv_mask.reshape(b, n_chunks, chunk), 1, 0)
    if scales is not None:
        ksc = jnp.moveaxis(scales[0].reshape(b, n_chunks, chunk, hkv), 1, 0)
        vsc = jnp.moveaxis(scales[1].reshape(b, n_chunks, chunk, hkv), 1, 0)
    else:  # dummy streams keep one scan signature
        ksc = jnp.zeros((n_chunks, b, 1, 1), jnp.float16)
        vsc = ksc

    def body(carry, inp):
        m, l, acc = carry
        k_c, v_c, mask_c, ks_c, vs_c = inp
        if scales is not None:
            k_c = dequantize_kv(k_c, ks_c, out_dtype)
            v_c = dequantize_kv(v_c, vs_c, out_dtype)
        logits = jnp.einsum(
            "bqhrd,bkhd->bhrqk", qg, k_c, preferred_element_type=jnp.float32
        ) * scale  # (b, hkv, n_rep, 1, chunk)
        logits = jnp.where(mask_c[:, None, None, None, :], logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1, keepdims=True))
        p = jnp.exp(logits - m_new)
        corr = jnp.exp(m - m_new)
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr[..., 0] * acc + jnp.einsum(
            "bhrqk,bkhd->bhrqd", p.astype(v_c.dtype), v_c,
            preferred_element_type=jnp.float32,
        )[..., 0, :]
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, n_rep, 1, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, n_rep, 1, 1), jnp.float32)
    a0 = jnp.zeros((b, hkv, n_rep, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, mc, ksc, vsc))
    out = acc / jnp.maximum(l[..., 0], 1e-30)
    return out.reshape(b, 1, hq, d).astype(out_dtype)


def attention_decode(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (b, 1, d_model)
    positions: jnp.ndarray,  # (b, 1) or (b, 3, 1) for mrope
    layer_cache: Dict,  # {"k": (b, cap, hkv, d), "v": (b, cap, hkv, d)}
    pos: jnp.ndarray,  # scalar int32: tokens cached so far
) -> Tuple[jnp.ndarray, Dict]:
    """One decode step against a (possibly ring, possibly int8) KV cache."""
    q, k_new, v_new = _project_qkv(p, cfg, x)
    q, k_new = _apply_positions(cfg, q, k_new, positions)
    quantized = "k_scale" in layer_cache
    cap = layer_cache["k"].shape[1]
    if cfg.sliding_window > 0 and cap == cfg.sliding_window:
        slot = pos % cap  # ring buffer
        wrapped = True
    else:
        slot = jnp.minimum(pos, cap - 1)  # linear cache
        wrapped = False
    new_cache = {}
    if quantized:
        kq, ks = quantize_kv(k_new)
        vq, vs = quantize_kv(v_new)
        new_cache["k"] = jax.lax.dynamic_update_slice(layer_cache["k"], kq, (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(layer_cache["v"], vq, (0, slot, 0, 0))
        new_cache["k_scale"] = jax.lax.dynamic_update_slice(
            layer_cache["k_scale"], ks, (0, slot, 0)
        )
        new_cache["v_scale"] = jax.lax.dynamic_update_slice(
            layer_cache["v_scale"], vs, (0, slot, 0)
        )
    else:
        new_cache["k"] = jax.lax.dynamic_update_slice(layer_cache["k"], k_new, (0, slot, 0, 0))
        new_cache["v"] = jax.lax.dynamic_update_slice(layer_cache["v"], v_new, (0, slot, 0, 0))
    # valid-key mask: ring buffers are fully valid once wrapped; linear caches
    # are valid up to the write slot (inclusive of the new token).
    idx = jnp.arange(cap)
    if wrapped:
        valid = (idx <= slot) | (pos >= cap)
    else:
        valid = idx <= slot
    kv_mask = jnp.broadcast_to(valid[None, :], (x.shape[0], cap))
    scales = (
        (new_cache["k_scale"], new_cache["v_scale"]) if quantized else None
    )
    if cap >= 8192 or quantized:
        out = decode_attention_chunked(
            q, new_cache["k"], new_cache["v"], kv_mask, chunk=min(2048, cap),
            scales=scales, out_dtype=x.dtype,
        )
    else:
        out = full_attention(q, new_cache["k"], new_cache["v"], causal=False, kv_mask=kv_mask)
    y = jnp.einsum(
        "...e,ed->...d", out.reshape(x.shape[0], 1, cfg.q_dim), p["wo"]
    )
    return y, new_cache
