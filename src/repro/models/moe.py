"""Mixture-of-Experts FFN: top-k token-choice routing with capacity-bounded
sort-based dispatch (TPU-native: sort/gather/scatter instead of one-hot
matmul dispatch, so HLO FLOPs stay honest — only expert matmuls count).

Layout follows the GShard/MaxText *grouped* formulation: tokens are split
into groups (sharded over the data axis); routing, sorting and capacity are
per-group, so no global sort crosses shard boundaries. Expert compute is an
einsum over (groups, experts, capacity, d) activations against (experts, d,
f) weights; expert-parallel vs. tensor-parallel placement is chosen by the
sharding rules (see dist/sharding.py) via logical-axis constraints.

``moe_reference`` is the dense oracle (every expert computed, gated sum)
used by unit/property tests.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.dist.api import constrain
from repro.models.layers import Params, dense_init


def moe_init(rng, cfg: ArchConfig, dtype) -> Params:
    kr, kg, ku, kd = jax.random.split(rng, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    return {
        "router": dense_init(kr, d, e, jnp.float32),  # router kept fp32
        "w_gate": jnp.stack([dense_init(k, d, f, dtype) for k in jax.random.split(kg, e)]),
        "w_up": jnp.stack([dense_init(k, d, f, dtype) for k in jax.random.split(ku, e)]),
        "w_down": jnp.stack([dense_init(k, f, d, dtype) for k in jax.random.split(kd, e)]),
    }


def default_capacity(group_size: int, top_k: int, n_experts: int, factor: float = 1.25) -> int:
    cap = int(group_size * top_k / n_experts * factor)
    cap = max(cap, top_k)  # never below top_k so tiny groups still route
    # round up to an MXU-friendly multiple
    return -(-cap // 8) * 8


# ---------------------------------------------------------------------------
# Routing (shared by dispatch + oracle)
# ---------------------------------------------------------------------------


def route(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """x: (..., d) -> (gate_vals (..., k) fp32, expert_idx (..., k) int32,
    router probs for aux loss)."""
    logits = jnp.einsum("...d,de->...e", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(jnp.sum(gate_vals, -1, keepdims=True), 1e-9)
    return gate_vals, expert_idx, probs


def load_balance_loss(probs: jnp.ndarray, expert_idx: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * p_e."""
    assign = jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.float32)  # (..., k, E)
    f = jnp.mean(jnp.sum(assign, axis=-2).reshape(-1, n_experts), axis=0)
    p = jnp.mean(probs.reshape(-1, n_experts), axis=0)
    return n_experts * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# Sort-based capacity dispatch (per group)
# ---------------------------------------------------------------------------


def _dispatch_indices(expert_idx: jnp.ndarray, n_experts: int, capacity: int):
    """Per-group routing tables.

    expert_idx: (S, k) int32. Returns:
      slot_table: (E, C) int32 — flat (s*k+j) id occupying each expert slot,
                  sentinel S*k when empty;
      slot_of_flat: (S*k,) int32 — flat slot id (e*C + c) per assignment,
                  sentinel E*C when dropped (capacity overflow).
    """
    s, k = expert_idx.shape
    n_flat = s * k
    flat_expert = expert_idx.reshape(-1)
    order = jnp.argsort(flat_expert, stable=True)  # token-order preserved per expert
    sorted_expert = flat_expert[order]
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat_expert].add(1)
    offsets = jnp.cumsum(counts) - counts
    pos_in_expert = jnp.arange(n_flat, dtype=jnp.int32) - offsets[sorted_expert]
    keep = pos_in_expert < capacity
    slot_table = jnp.full((n_experts, capacity), n_flat, jnp.int32)
    slot_table = slot_table.at[
        sorted_expert, jnp.where(keep, pos_in_expert, capacity)
    ].set(order, mode="drop")
    flat_slot = jnp.where(
        keep, sorted_expert * capacity + pos_in_expert, n_experts * capacity
    )
    slot_of_flat = jnp.zeros((n_flat,), jnp.int32).at[order].set(flat_slot)
    return slot_table, slot_of_flat


def _expert_ffn(p: Params, h: jnp.ndarray, act: str) -> jnp.ndarray:
    """h: (g, e, c, d) -> (g, e, c, d), batched per expert."""
    gate = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
    up = jnp.einsum("gecd,edf->gecf", h, p["w_up"])
    gate = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
    return jnp.einsum("gecf,efd->gecd", gate * up, p["w_down"])


def _moe_groups(
    p: Params, cfg: ArchConfig, xg: jnp.ndarray, cap: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dispatch + expert FFN + combine for a block of groups.
    xg: (g, g_size, d) -> (output (g, g_size, d), aux)."""
    n_groups, g_size, d = xg.shape
    gate_vals, expert_idx, probs = route(p["router"], xg, cfg.top_k)
    aux = load_balance_loss(probs, expert_idx, cfg.n_experts)

    slot_table, slot_of_flat = jax.vmap(
        lambda ei: _dispatch_indices(ei, cfg.n_experts, cap)
    )(expert_idx)

    # Gather expert inputs: sentinel row -> zeros.
    x_pad = jnp.concatenate([xg, jnp.zeros((n_groups, 1, d), xg.dtype)], axis=1)
    tok_idx = jnp.where(slot_table < g_size * cfg.top_k, slot_table // cfg.top_k, g_size)
    expert_in = jax.vmap(lambda xp, ti: xp[ti])(x_pad, tok_idx)  # (g, e, c, d)
    expert_in = constrain(expert_in, ("data", "expert", None, None))

    expert_out = _expert_ffn(p, expert_in, cfg.gated_act)
    expert_out = constrain(expert_out, ("data", "expert", None, None))

    # Combine: gather each assignment's slot output, weight by gates.
    out_flat = expert_out.reshape(n_groups, cfg.n_experts * cap, d)
    out_pad = jnp.concatenate(
        [out_flat, jnp.zeros((n_groups, 1, d), out_flat.dtype)], axis=1
    )
    contrib = jax.vmap(lambda op, sof: op[sof])(out_pad, slot_of_flat)
    contrib = contrib.reshape(n_groups, g_size, cfg.top_k, d)
    y = jnp.sum(contrib * gate_vals[..., None].astype(contrib.dtype), axis=2)
    return y, aux


def moe_apply(
    p: Params,
    cfg: ArchConfig,
    x: jnp.ndarray,  # (b, s, d)
    *,
    group_size: int = 4096,
    capacity_factor: float = 1.25,
    max_groups_per_block: int = 64,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (b,s,d), aux load-balance loss scalar).

    Groups beyond ``max_groups_per_block`` are processed by a lax.scan over
    group blocks, bounding the live dispatch tensors — 32k-token prefills
    would otherwise materialize (all_groups, E, C, d) gathers at once.
    """
    b, s, d = x.shape
    tokens = b * s
    g_size = min(group_size, tokens)
    while tokens % g_size:  # largest divisor of the token count <= group_size
        g_size -= 1
    n_groups = tokens // g_size
    xg = x.reshape(n_groups, g_size, d)
    cap = default_capacity(g_size, cfg.top_k, cfg.n_experts, capacity_factor)

    if n_groups <= max_groups_per_block or n_groups % max_groups_per_block:
        y, aux = _moe_groups(p, cfg, xg, cap)
        return y.reshape(b, s, d), aux

    n_blocks = n_groups // max_groups_per_block
    xb = xg.reshape(n_blocks, max_groups_per_block, g_size, d)

    def body(_, xblk):
        y, aux = _moe_groups(p, cfg, xblk, cap)
        return None, (y, aux)

    _, (yb, auxb) = jax.lax.scan(body, None, xb)
    return yb.reshape(b, s, d), jnp.mean(auxb)


# ---------------------------------------------------------------------------
# Dense oracle (tests): every expert computed, gated combination
# ---------------------------------------------------------------------------


def moe_reference(p: Params, cfg: ArchConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b, s, d = x.shape
    gate_vals, expert_idx, probs = route(p["router"], x, cfg.top_k)
    aux = load_balance_loss(probs, expert_idx, cfg.n_experts)
    act = cfg.gated_act
    outs = []
    for e in range(cfg.n_experts):
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"][e])
        up = jnp.einsum("bsd,df->bsf", x, p["w_up"][e])
        gate = jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate, approximate=True)
        outs.append(jnp.einsum("bsf,fd->bsd", gate * up, p["w_down"][e]))
    stacked = jnp.stack(outs, axis=2)  # (b, s, E, d)
    onehot = jax.nn.one_hot(expert_idx, cfg.n_experts, dtype=jnp.float32)
    w_full = jnp.sum(onehot * gate_vals[..., None], axis=-2)  # (b, s, E)
    y = jnp.einsum("bse,bsed->bsd", w_full.astype(stacked.dtype), stacked)
    return y, aux
